"""DEPRECATED: legacy single-table embedding-bag entry point.

The one-table-per-call Pallas kernel that used to live here (scalar-prefetch
gather, one grid step per (batch, lookup) pair) has been folded into the
multi-table fused engine: ``ops.embedding_bag`` wraps
``repro.kernels.fused_embedding`` with ``T=1``, so every caller shares one
combiner/weights contract (weights apply before sum/mean/max) and one
sparse-gradient custom VJP instead of a drifting second implementation.

This module remains as a thin re-export so external imports keep working:
``embedding_bag(table, indices, ..., interpret=True)`` maps onto
``ops.embedding_bag(..., impl="interpret")`` (the fused Pallas kernel in
interpret mode). It warns ``DeprecationWarning`` once per process — new code
should call ``repro.kernels.ops.embedding_bag`` with an ``EmbeddingPlan``.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import NEG_INF  # noqa: F401  (re-export, see tests)

_DEPRECATION_WARNED = False


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None, *,
                  combiner: str = "sum", interpret: bool = False) -> jnp.ndarray:
    """table (R, D); indices (B, n) int32; weights (B, n)? -> (B, D).

    Deprecated alias for ``ops.embedding_bag`` (the fused multi-table
    engine with T=1); ``interpret=True`` selects the Pallas kernel in
    interpret mode, otherwise the process-default impl applies.
    """
    global _DEPRECATION_WARNED
    if not _DEPRECATION_WARNED:
        _DEPRECATION_WARNED = True
        warnings.warn(
            "repro.kernels.embedding_bag is deprecated; use "
            "repro.kernels.ops.embedding_bag (fused engine, plan=...)",
            DeprecationWarning, stacklevel=2)
    from repro.kernels import ops
    from repro.sharding.policy import EmbeddingPlan
    return ops.embedding_bag(table, indices, weights,
                             plan=EmbeddingPlan(combiner=combiner),
                             impl="interpret" if interpret else None)
