"""Pallas TPU kernel: single-table embedding gather + segment pooling.

The paper's #1 hot spot: embedding-table lookups consume 30–48 % of DLRM
iteration time (§1, Fig 1a). On the CPU/PS architecture this is network+DRAM
traffic; on TPU we adapt it as a *scalar-prefetch gather*: the index tensor is
prefetched to SMEM, the grid walks (batch, lookup) pairs, and each step DMAs
exactly one embedding row HBM→VMEM via the BlockSpec index_map — no
materialized (B, n, D) gather tensor ever exists. Pooling (sum/mean/max)
accumulates in the revisited output block.

Weighted bags multiply each row by a per-(b, lookup) scalar prefetched to
SMEM *before* the combiner is applied, so weighted mean/max agree with
``ref.embedding_bag_ref`` (weights used to be silently ignored for any
combiner but "sum").

This is the legacy one-table-per-call kernel; the multi-table hot path lives
in ``repro.kernels.fused_embedding`` (one launch for all tables + sparse
VJP). ``ops.embedding_bag`` routes through the fused engine.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF


def _bag_kernel(idx_ref, table_row_ref, out_ref, *, n: int, combiner: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if combiner == "max":
            out_ref[...] = jnp.full_like(out_ref, NEG_INF)
        else:
            out_ref[...] = jnp.zeros_like(out_ref)

    row = table_row_ref[...].astype(jnp.float32)
    if combiner == "max":
        out_ref[...] = jnp.maximum(out_ref[...], row.astype(out_ref.dtype))
    else:
        out_ref[...] += row.astype(out_ref.dtype)

    if combiner == "mean":
        @pl.when(j == n - 1)
        def _fin():
            out_ref[...] = out_ref[...] / n


def _bag_kernel_weighted(idx_ref, w_ref, table_row_ref, out_ref, *, n: int,
                         combiner: str):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if combiner == "max":
            out_ref[...] = jnp.full_like(out_ref, NEG_INF)
        else:
            out_ref[...] = jnp.zeros_like(out_ref)

    row = table_row_ref[...].astype(jnp.float32) * w_ref[b, j]
    if combiner == "max":
        out_ref[...] = jnp.maximum(out_ref[...], row.astype(out_ref.dtype))
    else:
        out_ref[...] += row.astype(out_ref.dtype)

    if combiner == "mean":
        @pl.when(j == n - 1)
        def _fin():
            out_ref[...] = out_ref[...] / n


def embedding_bag(table: jnp.ndarray, indices: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None, *,
                  combiner: str = "sum", interpret: bool = False) -> jnp.ndarray:
    """table (R, D); indices (B, n) int32; weights (B, n)? -> (B, D)."""
    assert combiner in ("sum", "mean", "max"), combiner
    R, D = table.shape
    B, n = indices.shape
    indices = indices.astype(jnp.int32)

    if weights is not None:
        kernel = functools.partial(_bag_kernel_weighted, n=n, combiner=combiner)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,   # indices, weights
            grid=(B, n),
            in_specs=[pl.BlockSpec((1, D), lambda b, j, idx, w: (idx[b, j], 0))],
            out_specs=pl.BlockSpec((1, D), lambda b, j, idx, w: (b, 0)),
        )
        args = (indices, weights.astype(jnp.float32), table)
    else:
        kernel = functools.partial(_bag_kernel, n=n, combiner=combiner)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, n),
            in_specs=[pl.BlockSpec((1, D), lambda b, j, idx: (idx[b, j], 0))],
            out_specs=pl.BlockSpec((1, D), lambda b, j, idx: (b, 0)),
        )
        args = (indices, table)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(*args)
    return out
