"""minitron-8b — pruned nemotron dense LM, GQA(8). [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    layer_pattern=("global",),
    activation="silu",
    rope_theta=500000.0,
)
