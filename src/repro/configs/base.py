"""Config system: model architecture + input-shape + run configs.

Every assigned architecture gets one file in this package exporting ``CONFIG``
(the exact published config) and ``reduced()`` (a tiny same-family config for
CPU smoke tests). ``repro.configs.registry`` resolves ``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-pattern vocabulary
# ---------------------------------------------------------------------------
# "global"    : full causal self-attention
# "local"     : sliding-window causal self-attention (window = local_window)
# "recurrent" : RG-LRU recurrent block (recurrentgemma)
# "ssm"       : Mamba-2 SSD block
ATTN_KINDS = ("global", "local", "recurrent", "ssm")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering every family in the assigned pool."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm | dlrm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // n_heads

    # --- layer pattern -----------------------------------------------------
    # Repeating pattern of layer kinds, tiled (and truncated) to num_layers.
    # e.g. gemma3: ("local",)*5 + ("global",)  |  recurrentgemma:
    # ("recurrent","recurrent","local")  |  dense archs: ("global",)
    layer_pattern: Tuple[str, ...] = ("global",)
    local_window: int = 4096          # sliding-window size for "local" layers

    # --- attention details ---------------------------------------------------
    qk_norm: bool = False             # chameleon-style query/key RMSNorm
    attn_bias: bool = False
    logit_softcap: float = 0.0        # gemma-style attention logit soft-capping
    rope_theta: float = 500000.0
    rope_local_theta: Optional[float] = None  # distinct theta for local layers
    use_rope: bool = True             # whisper uses sinusoidal abs positions instead

    # --- MLP ------------------------------------------------------------------
    activation: str = "silu"          # silu (SwiGLU) | gelu (plain MLP)
    mlp_bias: bool = False

    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0                # 0 => dense MLP
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0                # N (state size per head)
    ssm_headdim: int = 64             # P
    ssm_expand: int = 2               # d_inner = expand * d_model
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256              # SSD chunk length

    # --- RG-LRU (recurrentgemma) ----------------------------------------------
    lru_width: Optional[int] = None

    # --- encoder/decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0           # 0 => decoder-only
    n_frames: int = 1500              # stub frontend: precomputed frame embeddings

    # --- embedding / head -------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_scale: bool = False         # gemma-style sqrt(d_model) embedding scaling

    # --- numerics ----------------------------------------------------------------
    # bf16 params + f32-master optimizer (production mixed precision): halves
    # FSDP all-gather and gradient all-reduce bytes vs f32 params. CPU smoke
    # tests override both to float32 via reduce_config.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "dlrm")
        for k in self.layer_pattern:
            assert k in ATTN_KINDS, k

    # ------------------------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind tuple of length num_layers (pattern tiled + truncated)."""
        pat = self.layer_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssm" for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer needs a full-length dense-attention KV cache."""
        return "global" not in self.layer_pattern

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    # --- parameter counting (analytic; cross-checked against real init) -------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; MoE can count only activated experts."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        total = v * d                                      # token embedding
        if not self.tie_embeddings:
            total += v * d                                 # lm head
        per_kind = {}
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.activation == "silu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        norms = 2 * d
        per_kind["global"] = attn + mlp + norms
        per_kind["local"] = attn + mlp + norms
        lru = self.lru_width or d
        per_kind["recurrent"] = (d * lru * 2 + lru * d + 2 * lru) + mlp + norms
        di, N, G, P = self.d_inner, self.ssm_state, self.ssm_ngroups, self.ssm_headdim
        nh_ssm = self.ssm_nheads
        ssm = (d * (2 * di + 2 * G * N + nh_ssm)          # in_proj
               + (di + 2 * G * N) * self.ssm_conv_width   # conv1d
               + nh_ssm * 2                                # A_log, D
               + di                                        # dt_bias ~ nh; norm
               + di * d)                                   # out_proj
        per_kind["ssm"] = ssm + norms
        if self.n_experts > 0:
            k = self.top_k if active_only else self.n_experts
            moe_mlp = k * (3 * ff * d if self.activation == "silu" else 2 * ff * d)
            per_kind["global"] = attn + moe_mlp + norms + d * self.n_experts
            per_kind["local"] = per_kind["global"]
        for kind in self.layer_kinds:
            total += per_kind[kind]
        if self.encoder_layers:
            total += self.encoder_layers * (per_kind["global"])
            total += self.num_layers * (d * nkv * hd * 2 + d)  # cross-attn kv+norm
        total += d                                          # final norm
        return int(total)


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for every LM arch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# smoke-test shape (CPU, reduced configs)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec-mandated skip rules; every skip is recorded in DESIGN/EXPERIMENTS."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, len(cfg.layer_pattern) + 1),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        local_window=16,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16,
        ssm_chunk=16,
        lru_width=64 if cfg.lru_width else None,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        n_frames=8,
        param_dtype="float32",
        compute_dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
