"""whisper-medium — encoder-decoder audio model. [arXiv:2212.04356]

Conv frontend is a STUB per assignment: ``input_specs`` provides precomputed
frame embeddings (batch, 1500, d_model); the transformer backbone (24 enc +
24 dec layers) is real. Decoder cross-attends to the encoder states.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,                # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    layer_pattern=("global",),
    activation="gelu",
    use_rope=False,               # sinusoidal absolute positions
    attn_bias=True,
    mlp_bias=True,
    n_frames=1500,
    tie_embeddings=True,
)
