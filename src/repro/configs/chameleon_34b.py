"""chameleon-34b — early-fusion VLM; VQ image tokens share the text vocab.

Backbone only (per assignment): the modality frontend is a stub; ``input_specs``
provides token ids drawn from the unified 65536 vocab (VQ codes + text).
QK-norm per the paper. [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    layer_pattern=("global",),
    activation="silu",
    qk_norm=True,
    rope_theta=10_000.0,
)
