"""Arch-id → config resolution for ``--arch <id>`` everywhere."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, shape_applicable,
)
from repro.configs import (
    llama3_2_3b, minitron_8b, gemma3_27b, command_r_35b, chameleon_34b,
    mamba2_2_7b, recurrentgemma_2b, whisper_medium, granite_moe_1b,
    mixtral_8x22b,
)
from repro.configs.dlrm_models import WIDE_DEEP, XDEEPFM, DCN, DLRMConfig

ARCHS: Dict[str, ModelConfig] = {
    "llama3.2-3b": llama3_2_3b.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "gemma3-27b": gemma3_27b.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "mamba2-2.7b": mamba2_2_7b.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
}

DLRMS: Dict[str, DLRMConfig] = {
    "wide_deep": WIDE_DEEP,
    "xdeepfm": XDEEPFM,
    "dcn": DCN,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; choose from {sorted(SHAPES)}")
    return SHAPES[name]


def get_dlrm(name: str) -> DLRMConfig:
    if name not in DLRMS:
        raise KeyError(f"unknown DLRM {name!r}; choose from {sorted(DLRMS)}")
    return DLRMS[name]


def all_cells():
    """All 40 (arch × shape) dry-run cells with applicability flags."""
    cells = []
    for arch_name, cfg in ARCHS.items():
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch_name, shape_name, ok, why))
    return cells
