"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427] Griffin architecture: repeating (recurrent, recurrent,
local-attention) blocks, MQA (kv=1), window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("recurrent", "recurrent", "local"),
    local_window=2048,
    lru_width=2560,
    activation="gelu",
    rope_theta=10_000.0,
    embed_scale=True,
    tie_embeddings=True,
)
