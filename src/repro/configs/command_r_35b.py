"""command-r-35b — dense LM, GQA(8), no biases. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    layer_pattern=("global",),
    activation="silu",
    attn_bias=False,
    mlp_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)
