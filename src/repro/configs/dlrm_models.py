"""The paper's own DLRM workloads: Wide&Deep (Model-X), xDeepFM (Model-Y), DCN (Model-Z).

Criteo-like feature layout: 13 dense (continuous) features + 26 categorical
sparse features, each with its own embedding table (§2.1 of the paper).
Batch size 512 matches the paper's evaluation setup (§6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class DLRMConfig:
    name: str
    kind: str                           # wide_deep | xdeepfm | dcn
    n_dense: int = 13
    n_tables: int = 26
    # rows per embedding table (hash-bucket sizes; heavy-tailed like Criteo)
    table_rows: Tuple[int, ...] = ()
    embed_dim: int = 16                 # D in the paper's Eqn 5 / §5.3
    mlp_dims: Tuple[int, ...] = (512, 256, 128)
    cross_layers: int = 3               # DCN
    cin_layers: Tuple[int, ...] = (128, 128)  # xDeepFM CIN feature maps
    batch_size: int = 512
    pooling: str = "sum"                # sum | mean | max (paper §2.1)
    multi_hot: int = 4                  # lookups per table per sample
    # power-law skew of the synthetic sparse-feature stream (0 = uniform);
    # α≈1.05 matches the heavy row-popularity skew RecShard reports
    zipf_alpha: float = 0.0
    # hot-row cache budget: total pooled rows mirrored into the fused
    # engine's VMEM cache (0 disables). Split per table by `table_hot`.
    hot_rows_k: int = 0

    def __post_init__(self):
        if not self.table_rows:
            # heavy-tailed bucket sizes, deterministic
            rows = tuple(
                int(10 ** (3 + 3 * ((i * 2654435761) % 100) / 100.0))
                for i in range(self.n_tables)
            )
            object.__setattr__(self, "table_rows", rows)

    @property
    def total_embedding_rows(self) -> int:
        return sum(self.table_rows)

    @property
    def table_offsets(self) -> Tuple[int, ...]:
        """Exclusive per-table row offsets into the pooled (R, D) table."""
        from repro.kernels.fused_embedding import table_offsets
        return table_offsets(self.table_rows)

    @property
    def table_hot(self) -> Optional[Tuple[int, ...]]:
        """Default per-table hot-prefix sizes for the fused engine's cache.

        Splits ``hot_rows_k`` evenly across tables (clipped to each table's
        rows, remainder to the leading tables) — the right default for the
        synthetic stream, whose skew is homogeneous across tables. The total
        never exceeds the ``hot_rows_k`` budget, which bounds the VMEM
        reservation. Frequency-aware jobs override this with
        ``repro.sharding.policy.pack_hot_ranges`` on measured counts.
        """
        if self.hot_rows_k <= 0:
            return None
        per, rem = divmod(self.hot_rows_k, self.n_tables)
        return tuple(min(int(r), per + (1 if t < rem else 0))
                     for t, r in enumerate(self.table_rows))

    def embedding_plan(self, *, table_hot=None, layout=None,
                       sparse_update: bool = False, block_b: int = 8):
        """The ``EmbeddingPlan`` this workload's fused embedding calls run
        under: the config's ``table_offsets``/``pooling`` plus the job's
        live knobs (measured cache plan, physical layout, fused sparse
        update). ``table_hot=None`` defaults to ``cfg.table_hot``.
        """
        from repro.sharding.policy import EmbeddingPlan
        return EmbeddingPlan(
            offsets=self.table_offsets, combiner=self.pooling,
            block_b=block_b,
            table_hot=self.table_hot if table_hot is None else
            tuple(int(k) for k in table_hot),
            layout=layout, sparse_update=sparse_update)

    def param_count(self) -> int:
        emb = self.total_embedding_rows * self.embed_dim
        d_in = self.n_dense + self.n_tables * self.embed_dim
        dense = 0
        prev = d_in
        for h in self.mlp_dims:
            dense += prev * h + h
            prev = h
        dense += prev * 1 + 1
        if self.kind == "dcn":
            dense += self.cross_layers * (2 * d_in + 1)
        if self.kind == "xdeepfm":
            prev_maps = self.n_tables
            for maps in self.cin_layers:
                dense += prev_maps * self.n_tables * maps
                prev_maps = maps
            dense += sum(self.cin_layers)
        if self.kind == "wide_deep":
            dense += self.total_embedding_rows  # wide (linear) part, 1-dim
        return emb + dense


WIDE_DEEP = DLRMConfig(name="wide_deep", kind="wide_deep")
XDEEPFM = DLRMConfig(name="xdeepfm", kind="xdeepfm")
DCN = DLRMConfig(name="dcn", kind="dcn")


def reduced_dlrm(cfg: DLRMConfig) -> DLRMConfig:
    import dataclasses
    return dataclasses.replace(
        cfg,
        n_dense=4,
        n_tables=6,
        table_rows=tuple([64] * 6),
        embed_dim=8,
        mlp_dims=(32, 16),
        cross_layers=2,
        cin_layers=(8, 8),
        batch_size=32,
        multi_hot=2,
    )
