"""mamba2-2.7b — attention-free SSM with SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
