from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, SMOKE_SHAPE, reduce_config, shape_applicable,
)
