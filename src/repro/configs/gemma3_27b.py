"""gemma3-27b — dense LM, 5:1 local:global attention, 128k ctx. [hf:google/gemma-3]

head_dim follows the HF release (128) rather than d_model//n_heads=168: the
assigned pool fixes (L, d_model, H, kv, d_ff, vocab) and leaves head_dim free;
128 is MXU-aligned and matches the published checkpoint.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    layer_pattern=("local",) * 5 + ("global",),
    local_window=1024,
    logit_softcap=0.0,
    rope_theta=1_000_000.0,
    rope_local_theta=10_000.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    qk_norm=True,
)
