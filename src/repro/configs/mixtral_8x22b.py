"""mixtral-8x22b — MoE LM, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    layer_pattern=("local",),     # SWA everywhere => sub-quadratic cache
    local_window=4096,
    activation="silu",
    n_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
)
