"""granite-moe-1b-a400m — MoE LM, 32 experts top-8, per-expert d_ff=512.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    layer_pattern=("global",),
    activation="silu",
    n_experts=32,
    top_k=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
