"""llama3.2-3b — dense decoder LM, GQA(8), SwiGLU. [hf:meta-llama/Llama-3.2-1B-family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    layer_pattern=("global",),
    activation="silu",
    rope_theta=500000.0,
    tie_embeddings=True,
)
